"""Paper Fig. 13: speedup / saving breakdown — the separate contributions
of MP-MRF (compute pruning) and On-Demand Fetching (byte pruning).

Computed from the analytic workload model at the paper's operating
points and measured wall-clock deltas on this host.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnergonConfig, energon_attention
from repro.core import performance_model as pm


def run():
    rows = []
    w = pm.AttentionWorkload(batch=1, heads=12, q_len=512, kv_len=512,
                             head_dim=64, pruning_ratio=8.0)
    f = pm.mpmrf_attention_flops(w)
    b = pm.mpmrf_attention_bytes(w)
    rows.append({
        "component": "mpmrf_flop_reduction",
        "factor": f["dense"] / (f["filter"] / 2 + f["attend"]),
        # (filter runs at int8 = 2x bf16 rate on the MXU)
        "note": "compute saved by filtering+sparse AU (paper: 8.3x)",
    })
    rows.append({
        "component": "odf_byte_reduction",
        "factor": b["dense"] / b["attend"],
        "note": "K/V bytes saved by On-Demand Fetching (paper: ~1.1-1.5x)",
    })

    # measured wall-clock split: filter-only vs attend-only vs dense
    rng = np.random.default_rng(0)
    B, H, n, d = 1, 8, 1024, 64
    q, k, v = (
        jnp.asarray(rng.normal(size=(B, H, n, d)), jnp.float32)
        for _ in range(3)
    )
    dense_fn = jax.jit(lambda q, k, v: energon_attention(
        q, k, v, EnergonConfig(impl="dense"), causal=True))
    sparse_fn = jax.jit(lambda q, k, v: energon_attention(
        q, k, v,
        EnergonConfig(impl="mpmrf_block", min_prune_layer=0,
                      pruning_ratio=8.0),
        causal=True))

    def t(fn):
        jax.block_until_ready(fn(q, k, v))
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn(q, k, v)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 3

    td, ts = t(dense_fn), t(sparse_fn)
    rows.append({
        "component": "measured_end_to_end",
        "factor": td / ts,
        "note": f"dense {td*1e3:.1f}ms vs energon {ts*1e3:.1f}ms (CPU)",
    })
    return rows


def main(emit):
    rows = run()
    for r in rows:
        emit(f"breakdown_{r['component']}", 0.0,
             f"factor={r['factor']:.2f}x {r['note']}")
    return rows
