"""Paper Fig. 11: attention throughput, dense vs Energon — plus the
serving engine's prefill/decode split.

Wall-clock on this host (CPU, jit-compiled) across sequence lengths for
dense / MP-MRF row / MP-MRF block paths, plus the analytic TPU-v5e
projection from the §IV-D-derived roofline model (the paper's own
speedup numbers come from its ASIC simulator, so the projection is the
comparable quantity). The serving section runs the chunked-prefill →
sparse-decode engine end-to-end and reports prefill and decode
tokens/s as separate rows.
"""

from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import EnergonConfig, energon_attention
from repro.core import performance_model as pm
from repro.models import LMModel
from repro.runtime import Request, ServeLoop, attention_cache_bytes


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    rows = []
    B, H, d = 1, 4, 64
    for n in (512, 1024, 2048):
        rng = np.random.default_rng(0)
        q, k, v = (
            jnp.asarray(rng.normal(size=(B, H, n, d)), jnp.float32)
            for _ in range(3)
        )
        impls = {
            "dense": EnergonConfig(impl="dense"),
            "mpmrf_row": EnergonConfig(impl="mpmrf_row", min_prune_layer=0),
            "mpmrf_block": EnergonConfig(
                impl="mpmrf_block", min_prune_layer=0, pruning_ratio=4.0
            ),
        }
        times = {}
        for name, cfg in impls.items():
            fn = jax.jit(
                lambda q, k, v, c=cfg: energon_attention(q, k, v, c,
                                                         causal=True)
            )
            times[name] = _time(fn, q, k, v)
        w = pm.AttentionWorkload(
            batch=B, heads=H, q_len=n, kv_len=n, head_dim=d,
            pruning_ratio=4.0,
        )
        proj = pm.tpu_attention_times(w)
        rows.append({
            "n": n,
            **{f"t_{k}": v for k, v in times.items()},
            "cpu_speedup_block": times["dense"] / times["mpmrf_block"],
            "tpu_projected_speedup": proj["speedup"],
        })
    return rows


def run_serving_engine(
    *,
    batch_slots: int = 4,
    max_len: int = 256,
    prompt_len: int = 48,
    prefill_chunk: int = 16,
    new_tokens: int = 16,
    n_requests: int = 8,
    pruning_ratio: float = 4.0,
):
    """End-to-end engine throughput: prefill vs decode, measured apart."""
    cfg = ModelConfig(
        name="bench-serve", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, dtype="float32", remat="none",
        energon=EnergonConfig(impl="mpmrf_block", min_prune_layer=1,
                              pruning_ratio=pruning_ratio,
                              decode_key_block=32),
    )
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeLoop(
        model, params, batch_slots=batch_slots, max_len=max_len,
        eos_token=cfg.vocab_size - 1, prefill_chunk=prefill_chunk,
    )
    rng = np.random.default_rng(0)
    # warm-up request compiles the prefill and decode programs so the
    # measured section reflects steady-state dispatch cost.
    engine.submit(Request(uid=0, prompt=rng.integers(
        1, cfg.vocab_size - 1, size=prompt_len).tolist(),
        max_new_tokens=new_tokens))
    engine.run_until_drained()
    engine.metrics = type(engine.metrics)()
    for uid in range(1, n_requests + 1):
        prompt = rng.integers(1, cfg.vocab_size - 1, size=prompt_len).tolist()
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=new_tokens))
    engine.run_until_drained()
    return engine.metrics


def _decode_step_traffic(
    *, filter_cache: bool, max_len: int, batch: int = 2
) -> float:
    """Per-decode-step HLO traffic bytes (post-fusion, whole model).

    Lowers the jitted one-token ``decode_step`` and walks the compiled
    HLO with ``analysis/hlo_costs`` — the while-loop-aware parser, so
    the scan-over-layers body is counted per layer. This is the number
    the filter-cache tentpole moves: with the persistent quantized
    cache, the per-step filter reads resident int16 planes instead of
    re-quantizing the O(max_len·d) cache.
    """
    from repro.analysis import hlo_costs

    cfg = ModelConfig(
        name="bench-decode-hlo", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, dtype="float32", remat="none",
        energon=EnergonConfig(
            impl="mpmrf_block", min_prune_layer=0, pruning_ratio=4.0,
            decode_key_block=64, filter_cache=filter_cache,
        ),
    )
    model = LMModel(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    inputs = {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
    }
    ci = jax.ShapeDtypeStruct((batch,), jnp.int32)
    compiled = (
        jax.jit(model.decode_step)
        .lower(params, cache, inputs, ci)
        .compile()
    )
    return float(hlo_costs.costs_from_compiled(compiled).traffic_bytes)


def run_decode_bench(
    *,
    max_len: int = 1024,
    engine_max_len: int = 256,
    prompt_len: int = 48,
    new_tokens: int = 16,
    n_requests: int = 6,
) -> dict:
    """Machine-readable decode-perf record (written to BENCH_decode.json).

    Tracks the quantities the perf trajectory cares about from this PR
    on: per-decode-step HLO traffic with the persistent filter cache vs
    the re-quantize-every-step baseline (at ``max_len`` rows), and the
    serving engine's prefill/decode tok/s at ρ=1 (keep-everything
    contract) and ρ=4 (the paper's headline pruning ratio).
    """
    record = {
        "schema": 1,
        "host_backend": jax.default_backend(),
        "hlo": {"max_len": max_len},
        "engine": {},
    }
    cached = _decode_step_traffic(filter_cache=True, max_len=max_len)
    fresh = _decode_step_traffic(filter_cache=False, max_len=max_len)
    record["hlo"]["decode_step_bytes_filter_cache"] = cached
    record["hlo"]["decode_step_bytes_requantize"] = fresh
    record["hlo"]["bytes_saved_per_step"] = fresh - cached
    record["hlo"]["traffic_ratio"] = cached / max(fresh, 1.0)

    # crossover gate: below FILTER_CACHE_AUTO_MIN_LEN the auto threshold
    # withholds the planes entirely, so the short-context build must not
    # pay the resident-plane overhead the 1.01 ratio at 512 used to show
    short_len = 512
    short_cached = _decode_step_traffic(filter_cache=True, max_len=short_len)
    short_fresh = _decode_step_traffic(filter_cache=False, max_len=short_len)
    record["hlo"]["short"] = {
        "max_len": short_len,
        "decode_step_bytes_filter_cache": short_cached,
        "decode_step_bytes_requantize": short_fresh,
        "traffic_ratio": short_cached / max(short_fresh, 1.0),
    }

    for label, ratio in (("rho1", 1.0), ("rho4", 4.0)):
        m = run_serving_engine(
            max_len=engine_max_len, prompt_len=prompt_len,
            new_tokens=new_tokens, n_requests=n_requests,
            pruning_ratio=ratio,
        )
        record["engine"][label] = {
            "pruning_ratio": ratio,
            "prefill_tok_s": m.prefill_tokens_per_sec,
            "decode_tok_s": m.decode_tokens_per_sec,
            **{
                f: getattr(m, f)
                for f in ("prefill_tokens", "decode_tokens",
                          "prefill_dispatches", "decode_dispatches")
            },
        }
    return record


def write_decode_json(path: str = "BENCH_decode.json", **kw) -> dict:
    record = run_decode_bench(**kw)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    return record


# ---------------------------------------------------------------------------
# Mixed-length serving trace: paged vs unpaged cache (BENCH_serving.json)
# ---------------------------------------------------------------------------

# 8–512 token prompts in arrival order — short and long requests
# interleaved so per-request sizing (paged) has stranded memory to win
# back from the single global max_len pad (unpaged).
SERVING_TRACE = (8, 16, 512, 32, 128, 64, 256, 384, 24, 48, 96, 192)


def _serve_model(pruning_ratio: float = 4.0, **energon_kw):
    energon_kw.setdefault("impl", "mpmrf_block")
    cfg = ModelConfig(
        name="bench-serve-trace", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, dtype="float32", remat="none",
        energon=EnergonConfig(min_prune_layer=1,
                              pruning_ratio=pruning_ratio,
                              decode_key_block=64, **energon_kw),
    )
    model = LMModel(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def run_serving_trace(
    *,
    paged: bool,
    num_pages=None,
    batch_slots: int = 4,
    max_len: int = 528,
    prefill_chunk: int = 64,
    new_tokens: int = 16,
    lengths=SERVING_TRACE,
    energon_kw=None,
):
    """Drain the mixed-length trace through one engine configuration.

    Returns ``(engine, completed, wall_seconds)``. The paged engine is
    deliberately oversubscribed (``num_pages`` < slots × blocks) so the
    run exercises continuous admission, eager frees and preemption —
    the unpaged engine on the same trace is the ``batch × max_len``
    footprint baseline.
    """
    cfg, model, params = _serve_model(**(energon_kw or {}))
    engine = ServeLoop(
        model, params, batch_slots=batch_slots, max_len=max_len,
        eos_token=cfg.vocab_size - 1, prefill_chunk=prefill_chunk,
        paged=paged, num_pages=num_pages,
    )
    rng = np.random.default_rng(0)
    for uid, L in enumerate(lengths):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(1, cfg.vocab_size - 1, size=int(L)).tolist(),
            max_new_tokens=new_tokens,
        ))
    t0 = time.perf_counter()
    done = engine.run_until_drained()
    wall = time.perf_counter() - t0
    assert len(done) == len(lengths), (len(done), len(lengths))
    return engine, done, wall


def run_serving_bench(*, num_pages: int = 16, new_tokens: int = 16) -> dict:
    """Machine-readable serving-trace record (BENCH_serving.json).

    Compares the paged engine (shared pool, continuous batching,
    preemption) against the unpaged engine on the same mixed-length
    trace: tok/s, peak pages in use, preemptions, and HBM cache bytes.
    The acceptance gate is ``paged peak bytes < unpaged bytes`` — the
    paged pool's *allocated* footprint is already below the
    ``batch × max_len`` pad, and the in-use watermark is lower still.
    """
    record = {
        "schema": 1,
        "host_backend": jax.default_backend(),
        "trace": {"prompt_lengths": list(SERVING_TRACE),
                  "new_tokens": new_tokens},
    }
    un_engine, un_done, un_wall = run_serving_trace(
        paged=False, new_tokens=new_tokens
    )
    unpaged_bytes = attention_cache_bytes(un_engine.cache)
    m = un_engine.metrics
    record["unpaged"] = {
        "cache_bytes": unpaged_bytes,
        "wall_seconds": un_wall,
        "prefill_tok_s": m.prefill_tokens_per_sec,
        "decode_tok_s": m.decode_tokens_per_sec,
        "total_tokens": sum(len(r.tokens_out) for r in un_done),
    }

    pg_engine, pg_done, pg_wall = run_serving_trace(
        paged=True, num_pages=num_pages, new_tokens=new_tokens
    )
    pool_bytes = attention_cache_bytes(pg_engine.cache)
    page_bytes = pool_bytes // pg_engine.layout.num_pages
    peak_pages = pg_engine.allocator.peak_pages_in_use
    m = pg_engine.metrics
    record["paged"] = {
        "num_pages": pg_engine.layout.num_pages,
        "page_size": pg_engine.layout.page_size,
        "pool_bytes": pool_bytes,
        "page_bytes": page_bytes,
        "peak_pages_in_use": peak_pages,
        "peak_cache_bytes": peak_pages * page_bytes,
        "preemptions": m.preemptions,
        "wall_seconds": pg_wall,
        "prefill_tok_s": m.prefill_tokens_per_sec,
        "decode_tok_s": m.decode_tokens_per_sec,
        "total_tokens": sum(len(r.tokens_out) for r in pg_done),
        "latency": m.latency_stats(),
    }
    record["paged_pool_vs_unpaged"] = pool_bytes / max(unpaged_bytes, 1)
    record["paged_peak_vs_unpaged"] = (
        peak_pages * page_bytes / max(unpaged_bytes, 1)
    )
    return record


def write_serving_json(path: str = "BENCH_serving.json", **kw) -> dict:
    record = run_serving_bench(**kw)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    return record


# ---------------------------------------------------------------------------
# Shared-system-prompt trace: prefix sharing on vs off (BENCH_prefix.json)
# ---------------------------------------------------------------------------

# Multi-user traffic with one shared system prompt: every request is
# system prompt + a short per-user suffix. Prefix sharing should turn
# the system-prompt prefill from O(requests) into O(1) — the trace is
# the ROADMAP's heavy-multi-user-traffic shape in miniature.
PREFIX_SYSTEM_LEN = 192           # 3 × decode_key_block(64) full pages
PREFIX_SUFFIX_LENS = (8, 24, 16, 40, 12, 32, 20, 28, 36, 4)


def run_prefix_trace(
    *,
    sharing: bool,
    batch_slots: int = 4,
    max_len: int = 320,
    prefill_chunk: int = 64,
    new_tokens: int = 8,
    system_len: int = PREFIX_SYSTEM_LEN,
    suffix_lens=PREFIX_SUFFIX_LENS,
):
    """Drain the shared-system-prompt trace through one paged engine
    (sharing on or off). Returns ``(engine, completed, wall_seconds,
    streams)`` — streams let the caller assert sharing is invisible."""
    cfg, model, params = _serve_model()
    engine = ServeLoop(
        model, params, batch_slots=batch_slots, max_len=max_len,
        eos_token=cfg.vocab_size - 1, prefill_chunk=prefill_chunk,
        paged=True, prefix_sharing=sharing,
    )
    rng = np.random.default_rng(7)
    system = rng.integers(1, cfg.vocab_size - 1, size=system_len).tolist()
    for uid, L in enumerate(suffix_lens):
        suffix = rng.integers(1, cfg.vocab_size - 1, size=int(L)).tolist()
        engine.submit(Request(
            uid=uid, prompt=system + suffix, max_new_tokens=new_tokens,
        ))
    t0 = time.perf_counter()
    done = engine.run_until_drained()
    wall = time.perf_counter() - t0
    assert len(done) == len(suffix_lens), (len(done), len(suffix_lens))
    streams = {r.uid: list(r.tokens_out) for r in done}
    return engine, done, wall, streams


def run_prefix_bench(*, new_tokens: int = 8) -> dict:
    """Machine-readable prefix-sharing record (BENCH_prefix.json).

    Same shared-system-prompt trace through the paged engine with
    sharing on and off: prefill tokens/dispatches (the shared run must
    do strictly less of both), hit rate, pages shared, CoW clones —
    and a hard equality check that both runs produced identical token
    streams (sharing must be invisible to outputs).
    """
    record = {
        "schema": 1,
        "host_backend": jax.default_backend(),
        "trace": {
            "system_prompt_len": PREFIX_SYSTEM_LEN,
            "suffix_lens": list(PREFIX_SUFFIX_LENS),
            "new_tokens": new_tokens,
        },
    }
    off_engine, _, off_wall, off_streams = run_prefix_trace(
        sharing=False, new_tokens=new_tokens
    )
    m = off_engine.metrics
    record["unshared"] = {
        "prefill_tokens": m.prefill_tokens,
        "prefill_dispatches": m.prefill_dispatches,
        "prefill_tok_s": m.prefill_tokens_per_sec,
        "decode_tok_s": m.decode_tokens_per_sec,
        "peak_pages_in_use": m.peak_pages_in_use,
        "wall_seconds": off_wall,
    }
    on_engine, _, on_wall, on_streams = run_prefix_trace(
        sharing=True, new_tokens=new_tokens
    )
    m = on_engine.metrics
    record["shared"] = {
        "prefill_tokens": m.prefill_tokens,
        "prefill_dispatches": m.prefill_dispatches,
        "prefill_tok_s": m.prefill_tokens_per_sec,
        "decode_tok_s": m.decode_tokens_per_sec,
        "peak_pages_in_use": m.peak_pages_in_use,
        "prefix_hit_rate": m.prefix_hit_rate,
        "prefix_hits": m.prefix_hits,
        "prefix_lookups": m.prefix_lookups,
        "pages_shared": m.pages_shared,
        "prefill_tokens_skipped": m.prefill_tokens_skipped,
        "cow_clones": m.cow_clones,
        "wall_seconds": on_wall,
    }
    record["streams_identical"] = on_streams == off_streams
    record["prefill_tokens_saved"] = (
        record["unshared"]["prefill_tokens"]
        - record["shared"]["prefill_tokens"]
    )
    return record


def write_prefix_json(path: str = "BENCH_prefix.json", **kw) -> dict:
    record = run_prefix_bench(**kw)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    return record


# ---------------------------------------------------------------------------
# Seeded fault storm: goodput + the fault-invisibility contract
# (BENCH_chaos.json)
# ---------------------------------------------------------------------------

# Mixed greedy/stochastic temperatures over the serving trace: the
# fault-invisibility contract must hold for both sampling regimes.
CHAOS_TEMPS = (0.0, 0.7)


def run_chaos_trace(
    *,
    injector=None,
    batch_slots: int = 4,
    max_len: int = 528,
    num_pages: int = 20,
    new_tokens: int = 16,
    lengths=SERVING_TRACE,
):
    """Drain the mixed-length trace through a paged engine, optionally
    under a :class:`FaultInjector`. Returns ``(engine, streams)`` where
    ``streams`` maps uid → token list for *completed* requests only."""
    cfg, model, params = _serve_model()
    engine = ServeLoop(
        model, params, batch_slots=batch_slots, max_len=max_len,
        eos_token=cfg.vocab_size - 1, prefill_chunk=64,
        paged=True, num_pages=num_pages, fault_injector=injector,
        audit=True,
    )
    rng = np.random.default_rng(0)
    for uid, L in enumerate(lengths):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(1, cfg.vocab_size - 1, size=int(L)).tolist(),
            max_new_tokens=new_tokens,
            temperature=CHAOS_TEMPS[uid % len(CHAOS_TEMPS)],
        ))
    done = engine.run_until_drained(max_ticks=50_000)
    return engine, {r.uid: list(r.tokens_out) for r in done}


def run_chaos_bench(*, seed: int = 1234, new_tokens: int = 16) -> dict:
    """Machine-readable chaos record (BENCH_chaos.json).

    Runs the serving trace clean, then again under a seeded fault storm
    (allocation denials, retried step exceptions, NaN-poisoned logits,
    forced preemption storms), and checks the fault-invisibility
    contract: every surviving request's stream bit-identical to the
    clean run, zero healthy requests lost, goodput + lifecycle counters
    reported. The same seed replays the same fault schedule — a red CI
    run reproduces locally byte-for-byte.
    """
    from repro.runtime import FaultInjector, FaultSpec

    spec = FaultSpec(
        alloc_failure=0.08,
        step_exception=0.08, step_exception_burst=2,
        nan_logits=0.004, nan_prefill=0.02,
        preempt_storm=0.04, preempt_storm_size=2,
    )
    record = {
        "schema": 1,
        "host_backend": jax.default_backend(),
        "seed": seed,
        "spec": dataclasses.asdict(spec),
        "trace": {"prompt_lengths": list(SERVING_TRACE),
                  "new_tokens": new_tokens,
                  "temperatures": list(CHAOS_TEMPS)},
    }
    _, clean_streams = run_chaos_trace(new_tokens=new_tokens)

    injector = FaultInjector(seed=seed, spec=spec)
    t0 = time.perf_counter()
    engine, chaos_streams = run_chaos_trace(
        injector=injector, new_tokens=new_tokens
    )
    wall = time.perf_counter() - t0
    m = engine.metrics
    survivors = sorted(chaos_streams)
    faulted = sorted(r.uid for r in engine.terminated)
    # every request must reach *a* terminal state (drained ⇒ none stuck)
    lost = sorted(
        set(range(len(SERVING_TRACE))) - set(survivors) - set(faulted)
    )
    goodput_tokens = sum(len(t) for t in chaos_streams.values())
    record["chaos"] = {
        "wall_seconds": wall,
        "completed": len(survivors),
        "faulted": faulted,
        "lost_healthy": lost,
        "goodput_tokens": goodput_tokens,
        "goodput_tok_s": goodput_tokens / max(wall, 1e-9),
        "preemptions": m.preemptions,
        "retries": m.retries,
        "failed_requests": m.failed_requests,
        "faults_injected": dict(injector.counts),
        "total_faults_injected": injector.total_injected,
    }
    record["survivors_identical"] = all(
        chaos_streams[u] == clean_streams[u] for u in survivors
    )
    return record


def write_chaos_json(path: str = "BENCH_chaos.json", **kw) -> dict:
    record = run_chaos_bench(**kw)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    return record


# ---------------------------------------------------------------------------
# Fused prefill: survivor-only K/V streaming vs XLA re-quantize
# (BENCH_prefill.json)
# ---------------------------------------------------------------------------

PREFILL_CONTEXTS = (512, 1024, 2048)


def _prefill_chunk_xla_bytes(
    *, n_k: int, chunk: int = 64, batch: int = 2, heads: int = 4,
    head_dim: int = 16, key_block: int = 64,
) -> float:
    """HLO traffic bytes for one XLA-path prefill chunk at the attention op.

    Compiles ``energon_attention`` with ``impl="mpmrf_block"`` and *no*
    filter cache — the path that re-quantizes the whole resident K cache
    and materializes both bit planes in HBM for every chunk. Measured at
    the attention op (not the whole model) so the MLP does not dilute
    the number the fused kernel actually moves.
    """
    from repro.analysis import hlo_costs
    from repro.core import EnergonConfig as ECfg
    from repro.core import energon_attention

    cfg = ECfg(
        impl="mpmrf_block", pruning_ratio=4.0, min_prune_layer=0,
        query_block=chunk, key_block=key_block, decode_key_block=key_block,
    )
    q = jax.ShapeDtypeStruct((batch, heads, chunk, head_dim), jnp.float32)
    kv = jax.ShapeDtypeStruct((batch, heads, n_k, head_dim), jnp.float32)
    qpos = jax.ShapeDtypeStruct((batch, chunk), jnp.int32)

    def fn(q, k, v, q_positions):
        return energon_attention(
            q, k, v, cfg, q_positions=q_positions, layer_index=5,
        )

    compiled = jax.jit(fn).lower(q, kv, kv, qpos).compile()
    return float(hlo_costs.costs_from_compiled(compiled).traffic_bytes)


def run_prefill_bench(
    *, contexts=PREFILL_CONTEXTS, chunk: int = 64, new_tokens: int = 8,
) -> dict:
    """Machine-readable fused-prefill record (BENCH_prefill.json).

    Two sections. ``hlo``: per-chunk attention-op traffic at each
    resident context length — the XLA re-quantize path costed from its
    compiled HLO vs the fused Pallas path priced analytically from its
    BlockSpec geometry (``analysis/kernel_traffic``; interpret-mode HLO
    on a CPU host reflects the emulation, not the kernel's tile
    streams, so the fused side is closed-form by construction).
    ``engine``: end-to-end prefill tok/s on the mixed serving trace,
    fused prefill on (``impl="pallas"``) vs off, planes resident in
    both so only the prefill path differs.
    """
    import math as _math

    from repro.analysis import kernel_traffic

    batch, heads, head_dim, key_block = 2, 4, 16, 64
    record = {
        "schema": 1,
        "host_backend": jax.default_backend(),
        "hlo": {"chunk": chunk, "contexts": list(contexts)},
        "engine": {},
    }
    for n_k in contexts:
        xla = _prefill_chunk_xla_bytes(
            n_k=n_k, chunk=chunk, batch=batch, heads=heads,
            head_dim=head_dim, key_block=key_block,
        )
        n_kb = n_k // key_block
        fused = kernel_traffic.fused_prefill_traffic(
            bh=batch * heads, n_q=chunk, n_k=n_k, d=head_dim,
            query_block=chunk, key_block=key_block,
            filter_block=key_block,
            block_budget=max(1, _math.ceil(n_kb / 4.0)),
        )
        record["hlo"][str(n_k)] = {
            "xla_requantize_bytes": xla,
            "fused_bytes": float(fused.total_bytes),
            "fused_breakdown": {
                "quantize": fused.quantize_bytes,
                "filter": fused.filter_bytes,
                "select": fused.select_bytes,
                "gather": fused.gather_bytes,
            },
            "bytes_saved": xla - fused.total_bytes,
            "traffic_ratio": fused.total_bytes / max(xla, 1.0),
        }

    # On CPU (and any backend without a Pallas lowering) the fused path
    # runs in *interpret* mode — a per-element Python/XLA emulation whose
    # wall-clock says nothing about kernel performance, so the engine
    # section is labeled and CI asserts only on the analytic traffic
    # model when interpreting.
    from repro.kernels.ops import _default_interpret

    record["engine"]["kernel_mode"] = (
        "interpret" if _default_interpret() else "compiled"
    )
    for label, energon_kw in (
        ("fused", {"impl": "pallas", "filter_cache_min_len": 0}),
        ("xla", {"impl": "mpmrf_block", "filter_cache_min_len": 0}),
    ):
        engine, done, wall = run_serving_trace(
            paged=False, new_tokens=new_tokens, energon_kw=energon_kw,
        )
        m = engine.metrics
        record["engine"][label] = {
            "prefill_tok_s": m.prefill_tokens_per_sec,
            "decode_tok_s": m.decode_tokens_per_sec,
            "prefill_tokens": m.prefill_tokens,
            "prefill_dispatches": m.prefill_dispatches,
            "wall_s": wall,
            "completed": len(done),
        }
    return record


def write_prefill_json(path: str = "BENCH_prefill.json", **kw) -> dict:
    record = run_prefill_bench(**kw)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    return record


# ---------------------------------------------------------------------------
# Observability overhead: tracing on vs off on the serving trace
# (BENCH_obs.json)
# ---------------------------------------------------------------------------


def run_obs_bench(
    *, repeats: int = 3, new_tokens: int = 16, trace_out=None,
) -> dict:
    """Machine-readable observability record (BENCH_obs.json).

    Drains the mixed-length serving trace through a paged engine with
    the observability layer detached and attached (device telemetry on:
    per-dispatch survivor-block counts, event tracing, per-tick series).
    Each configuration warms its compiled programs on one throwaway
    request, then runs the trace ``repeats`` times on the warm engine
    and keeps the best decode tok/s — the overhead gate compares
    best-of-N so host noise cannot fabricate a regression. Also checks
    the token streams are bit-identical with tracing on, reports ρ_eff,
    and schema-validates the exported Chrome trace (optionally written
    to ``trace_out`` for the CI artifact).
    """
    from repro.observability import Observability, validate_chrome_trace

    record = {
        "schema": 1,
        "host_backend": jax.default_backend(),
        "repeats": repeats,
        "trace": {"prompt_lengths": list(SERVING_TRACE),
                  "new_tokens": new_tokens},
    }
    streams_by = {}
    obs = None
    for label in ("off", "on"):
        obs_obj = Observability() if label == "on" else None
        cfg, model, params = _serve_model()
        engine = ServeLoop(
            model, params, batch_slots=4, max_len=528,
            eos_token=cfg.vocab_size - 1, prefill_chunk=64,
            paged=True, num_pages=20, observability=obs_obj,
        )
        rng = np.random.default_rng(9)
        engine.submit(Request(uid=0, prompt=rng.integers(
            1, cfg.vocab_size - 1, size=48).tolist(),
            max_new_tokens=new_tokens))
        engine.run_until_drained()
        best_decode = 0.0
        streams = []
        for rep in range(repeats):
            engine.metrics = type(engine.metrics)(
                registry=obs_obj.registry if obs_obj else None
            )
            rng = np.random.default_rng(0)
            reqs = []
            for uid, L in enumerate(SERVING_TRACE):
                req = Request(
                    uid=1000 * (rep + 1) + uid,
                    prompt=rng.integers(
                        1, cfg.vocab_size - 1, size=int(L)
                    ).tolist(),
                    max_new_tokens=new_tokens,
                )
                reqs.append(req)
                engine.submit(req)
            engine.run_until_drained(max_ticks=50_000)
            assert all(r.done for r in reqs)
            streams.append({r.uid % 1000: list(r.tokens_out)
                            for r in reqs})
            best_decode = max(best_decode,
                              engine.metrics.decode_tokens_per_sec)
        streams_by[label] = streams
        record[label] = {"decode_tok_s_best": best_decode}
        if obs_obj is not None:
            obs = obs_obj
            sp = obs_obj.sparsity.snapshot()
            record[label]["rho_eff_decode"] = sp["decode"]["rho_eff"]
            record[label]["rho_eff_prefill"] = sp["prefill"]["rho_eff"]
            record[label]["trace_events"] = len(obs_obj.trace)
            record[label]["trace_dropped"] = obs_obj.trace.dropped
    record["streams_identical"] = streams_by["on"] == streams_by["off"]
    record["overhead_pct"] = (
        record["off"]["decode_tok_s_best"]
        / max(record["on"]["decode_tok_s_best"], 1e-9) - 1.0
    ) * 100.0
    doc = obs.export_chrome_trace(trace_out)
    validate_chrome_trace(doc)
    record["chrome_trace_valid"] = True
    if trace_out is not None:
        record["chrome_trace_path"] = trace_out
    return record


def write_obs_json(path: str = "BENCH_obs.json", **kw) -> dict:
    record = run_obs_bench(**kw)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    return record


def main(emit):
    rows = run()
    for r in rows:
        emit(
            f"throughput_n{r['n']}_dense", r["t_dense"] * 1e6,
            "cpu wall-time",
        )
        emit(
            f"throughput_n{r['n']}_mpmrf_block", r["t_mpmrf_block"] * 1e6,
            f"cpu_speedup={r['cpu_speedup_block']:.2f}x "
            f"tpu_projected={r['tpu_projected_speedup']:.2f}x",
        )
    m = run_serving_engine()
    emit(
        "serve_prefill", m.prefill_time / max(m.prefill_dispatches, 1) * 1e6,
        f"prefill_tok_s={m.prefill_tokens_per_sec:.1f} "
        f"tokens={m.prefill_tokens} dispatches={m.prefill_dispatches}",
    )
    emit(
        "serve_decode", m.decode_time / max(m.decode_dispatches, 1) * 1e6,
        f"decode_tok_s={m.decode_tokens_per_sec:.1f} "
        f"tokens={m.decode_tokens} dispatches={m.decode_dispatches}",
    )
    # aggregate runner: emit the trajectory numbers without dropping a
    # JSON file into the cwd (the __main__ CLI / CI smoke writes it)
    rec = run_decode_bench()
    emit(
        "decode_step_hlo_bytes",
        rec["hlo"]["decode_step_bytes_filter_cache"],
        f"requantize={rec['hlo']['decode_step_bytes_requantize']:.0f} "
        f"ratio={rec['hlo']['traffic_ratio']:.3f}",
    )
    return rows


if __name__ == "__main__":
    # Standalone bench entries (CI smokes): --json writes the decode
    # record, --serving-json the paged-vs-unpaged serving-trace record.
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write BENCH_decode.json to this path")
    ap.add_argument("--serving-json", default=None,
                    help="write BENCH_serving.json to this path")
    ap.add_argument("--prefix-json", default=None,
                    help="write BENCH_prefix.json (shared-system-prompt "
                         "trace, prefix sharing on vs off) to this path")
    ap.add_argument("--prefill-json", default=None,
                    help="write BENCH_prefill.json (fused Pallas prefill "
                         "traffic vs XLA re-quantize + trace tok/s) to "
                         "this path")
    ap.add_argument("--chaos-json", default=None,
                    help="write BENCH_chaos.json (serving trace under a "
                         "seeded fault storm: goodput, retry/eviction "
                         "counts, fault-invisibility check) to this path")
    ap.add_argument("--chaos-seed", type=int, default=1234,
                    help="FaultInjector seed for --chaos-json (same seed "
                         "⇒ same fault schedule)")
    ap.add_argument("--obs-json", default=None,
                    help="write BENCH_obs.json (serving trace with the "
                         "observability layer on vs off: decode tok/s "
                         "overhead, rho_eff, Chrome-trace validity) to "
                         "this path")
    ap.add_argument("--obs-trace", default=None,
                    help="also write the --obs-json run's Chrome/"
                         "Perfetto trace to this path (CI artifact)")
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=16,
                    help="paged pool size for the serving trace "
                         "(oversubscribed below slots*blocks)")
    args = ap.parse_args()
    if (args.json is None and args.serving_json is None
            and args.prefix_json is None and args.prefill_json is None
            and args.chaos_json is None and args.obs_json is None):
        args.json = "BENCH_decode.json"
    if args.json is not None:
        out = write_decode_json(
            args.json, max_len=args.max_len, n_requests=args.requests,
            new_tokens=args.new_tokens,
        )
        print(json.dumps(out, indent=2, sort_keys=True))
    if args.serving_json is not None:
        out = write_serving_json(
            args.serving_json, num_pages=args.num_pages,
            new_tokens=args.new_tokens,
        )
        print(json.dumps(out, indent=2, sort_keys=True))
    if args.prefix_json is not None:
        out = write_prefix_json(
            args.prefix_json, new_tokens=args.new_tokens,
        )
        print(json.dumps(out, indent=2, sort_keys=True))
    if args.prefill_json is not None:
        out = write_prefill_json(args.prefill_json)
        print(json.dumps(out, indent=2, sort_keys=True))
    if args.chaos_json is not None:
        out = write_chaos_json(
            args.chaos_json, seed=args.chaos_seed,
            new_tokens=args.new_tokens,
        )
        print(json.dumps(out, indent=2, sort_keys=True))
    if args.obs_json is not None:
        out = write_obs_json(
            args.obs_json, new_tokens=args.new_tokens,
            trace_out=args.obs_trace,
        )
        print(json.dumps(out, indent=2, sort_keys=True))
