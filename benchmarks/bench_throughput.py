"""Paper Fig. 11: attention throughput, dense vs Energon.

Wall-clock on this host (CPU, jit-compiled) across sequence lengths for
dense / MP-MRF row / MP-MRF block paths, plus the analytic TPU-v5e
projection from the §IV-D-derived roofline model (the paper's own
speedup numbers come from its ASIC simulator, so the projection is the
comparable quantity).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnergonConfig, energon_attention
from repro.core import performance_model as pm


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    rows = []
    B, H, d = 1, 4, 64
    for n in (512, 1024, 2048):
        rng = np.random.default_rng(0)
        q, k, v = (
            jnp.asarray(rng.normal(size=(B, H, n, d)), jnp.float32)
            for _ in range(3)
        )
        impls = {
            "dense": EnergonConfig(impl="dense"),
            "mpmrf_row": EnergonConfig(impl="mpmrf_row", min_prune_layer=0),
            "mpmrf_block": EnergonConfig(
                impl="mpmrf_block", min_prune_layer=0, pruning_ratio=4.0
            ),
        }
        times = {}
        for name, cfg in impls.items():
            fn = jax.jit(
                lambda q, k, v, c=cfg: energon_attention(q, k, v, c,
                                                         causal=True)
            )
            times[name] = _time(fn, q, k, v)
        w = pm.AttentionWorkload(
            batch=B, heads=H, q_len=n, kv_len=n, head_dim=d,
            pruning_ratio=4.0,
        )
        proj = pm.tpu_attention_times(w)
        rows.append({
            "n": n,
            **{f"t_{k}": v for k, v in times.items()},
            "cpu_speedup_block": times["dense"] / times["mpmrf_block"],
            "tpu_projected_speedup": proj["speedup"],
        })
    return rows


def main(emit):
    rows = run()
    for r in rows:
        emit(
            f"throughput_n{r['n']}_dense", r["t_dense"] * 1e6,
            "cpu wall-time",
        )
        emit(
            f"throughput_n{r['n']}_mpmrf_block", r["t_mpmrf_block"] * 1e6,
            f"cpu_speedup={r['cpu_speedup_block']:.2f}x "
            f"tpu_projected={r['tpu_projected_speedup']:.2f}x",
        )
    return rows
