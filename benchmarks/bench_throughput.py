"""Paper Fig. 11: attention throughput, dense vs Energon — plus the
serving engine's prefill/decode split.

Wall-clock on this host (CPU, jit-compiled) across sequence lengths for
dense / MP-MRF row / MP-MRF block paths, plus the analytic TPU-v5e
projection from the §IV-D-derived roofline model (the paper's own
speedup numbers come from its ASIC simulator, so the projection is the
comparable quantity). The serving section runs the chunked-prefill →
sparse-decode engine end-to-end and reports prefill and decode
tokens/s as separate rows.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import EnergonConfig, energon_attention
from repro.core import performance_model as pm
from repro.models import LMModel
from repro.runtime import Request, ServeLoop


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    rows = []
    B, H, d = 1, 4, 64
    for n in (512, 1024, 2048):
        rng = np.random.default_rng(0)
        q, k, v = (
            jnp.asarray(rng.normal(size=(B, H, n, d)), jnp.float32)
            for _ in range(3)
        )
        impls = {
            "dense": EnergonConfig(impl="dense"),
            "mpmrf_row": EnergonConfig(impl="mpmrf_row", min_prune_layer=0),
            "mpmrf_block": EnergonConfig(
                impl="mpmrf_block", min_prune_layer=0, pruning_ratio=4.0
            ),
        }
        times = {}
        for name, cfg in impls.items():
            fn = jax.jit(
                lambda q, k, v, c=cfg: energon_attention(q, k, v, c,
                                                         causal=True)
            )
            times[name] = _time(fn, q, k, v)
        w = pm.AttentionWorkload(
            batch=B, heads=H, q_len=n, kv_len=n, head_dim=d,
            pruning_ratio=4.0,
        )
        proj = pm.tpu_attention_times(w)
        rows.append({
            "n": n,
            **{f"t_{k}": v for k, v in times.items()},
            "cpu_speedup_block": times["dense"] / times["mpmrf_block"],
            "tpu_projected_speedup": proj["speedup"],
        })
    return rows


def run_serving_engine(
    *,
    batch_slots: int = 4,
    max_len: int = 256,
    prompt_len: int = 48,
    prefill_chunk: int = 16,
    new_tokens: int = 16,
    n_requests: int = 8,
    pruning_ratio: float = 4.0,
):
    """End-to-end engine throughput: prefill vs decode, measured apart."""
    cfg = ModelConfig(
        name="bench-serve", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, dtype="float32", remat="none",
        energon=EnergonConfig(impl="mpmrf_block", min_prune_layer=1,
                              pruning_ratio=pruning_ratio,
                              decode_key_block=32),
    )
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeLoop(
        model, params, batch_slots=batch_slots, max_len=max_len,
        eos_token=cfg.vocab_size - 1, prefill_chunk=prefill_chunk,
    )
    rng = np.random.default_rng(0)
    # warm-up request compiles the prefill and decode programs so the
    # measured section reflects steady-state dispatch cost.
    engine.submit(Request(uid=0, prompt=rng.integers(
        1, cfg.vocab_size - 1, size=prompt_len).tolist(),
        max_new_tokens=new_tokens))
    engine.run_until_drained()
    engine.metrics = type(engine.metrics)()
    for uid in range(1, n_requests + 1):
        prompt = rng.integers(1, cfg.vocab_size - 1, size=prompt_len).tolist()
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=new_tokens))
    engine.run_until_drained()
    return engine.metrics


def _decode_step_traffic(
    *, filter_cache: bool, max_len: int, batch: int = 2
) -> float:
    """Per-decode-step HLO traffic bytes (post-fusion, whole model).

    Lowers the jitted one-token ``decode_step`` and walks the compiled
    HLO with ``analysis/hlo_costs`` — the while-loop-aware parser, so
    the scan-over-layers body is counted per layer. This is the number
    the filter-cache tentpole moves: with the persistent quantized
    cache, the per-step filter reads resident int16 planes instead of
    re-quantizing the O(max_len·d) cache.
    """
    from repro.analysis import hlo_costs

    cfg = ModelConfig(
        name="bench-decode-hlo", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, dtype="float32", remat="none",
        energon=EnergonConfig(
            impl="mpmrf_block", min_prune_layer=0, pruning_ratio=4.0,
            decode_key_block=64, filter_cache=filter_cache,
        ),
    )
    model = LMModel(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    inputs = {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
    }
    ci = jax.ShapeDtypeStruct((batch,), jnp.int32)
    compiled = (
        jax.jit(model.decode_step)
        .lower(params, cache, inputs, ci)
        .compile()
    )
    return float(hlo_costs.costs_from_compiled(compiled).traffic_bytes)


def run_decode_bench(
    *,
    max_len: int = 1024,
    engine_max_len: int = 256,
    prompt_len: int = 48,
    new_tokens: int = 16,
    n_requests: int = 6,
) -> dict:
    """Machine-readable decode-perf record (written to BENCH_decode.json).

    Tracks the quantities the perf trajectory cares about from this PR
    on: per-decode-step HLO traffic with the persistent filter cache vs
    the re-quantize-every-step baseline (at ``max_len`` rows), and the
    serving engine's prefill/decode tok/s at ρ=1 (keep-everything
    contract) and ρ=4 (the paper's headline pruning ratio).
    """
    record = {
        "schema": 1,
        "host_backend": jax.default_backend(),
        "hlo": {"max_len": max_len},
        "engine": {},
    }
    cached = _decode_step_traffic(filter_cache=True, max_len=max_len)
    fresh = _decode_step_traffic(filter_cache=False, max_len=max_len)
    record["hlo"]["decode_step_bytes_filter_cache"] = cached
    record["hlo"]["decode_step_bytes_requantize"] = fresh
    record["hlo"]["bytes_saved_per_step"] = fresh - cached
    record["hlo"]["traffic_ratio"] = cached / max(fresh, 1.0)

    for label, ratio in (("rho1", 1.0), ("rho4", 4.0)):
        m = run_serving_engine(
            max_len=engine_max_len, prompt_len=prompt_len,
            new_tokens=new_tokens, n_requests=n_requests,
            pruning_ratio=ratio,
        )
        record["engine"][label] = {
            "pruning_ratio": ratio,
            "prefill_tok_s": m.prefill_tokens_per_sec,
            "decode_tok_s": m.decode_tokens_per_sec,
            **{
                f: getattr(m, f)
                for f in ("prefill_tokens", "decode_tokens",
                          "prefill_dispatches", "decode_dispatches")
            },
        }
    return record


def write_decode_json(path: str = "BENCH_decode.json", **kw) -> dict:
    record = run_decode_bench(**kw)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    return record


def main(emit):
    rows = run()
    for r in rows:
        emit(
            f"throughput_n{r['n']}_dense", r["t_dense"] * 1e6,
            "cpu wall-time",
        )
        emit(
            f"throughput_n{r['n']}_mpmrf_block", r["t_mpmrf_block"] * 1e6,
            f"cpu_speedup={r['cpu_speedup_block']:.2f}x "
            f"tpu_projected={r['tpu_projected_speedup']:.2f}x",
        )
    m = run_serving_engine()
    emit(
        "serve_prefill", m.prefill_time / max(m.prefill_dispatches, 1) * 1e6,
        f"prefill_tok_s={m.prefill_tokens_per_sec:.1f} "
        f"tokens={m.prefill_tokens} dispatches={m.prefill_dispatches}",
    )
    emit(
        "serve_decode", m.decode_time / max(m.decode_dispatches, 1) * 1e6,
        f"decode_tok_s={m.decode_tokens_per_sec:.1f} "
        f"tokens={m.decode_tokens} dispatches={m.decode_dispatches}",
    )
    # aggregate runner: emit the trajectory numbers without dropping a
    # JSON file into the cwd (the __main__ CLI / CI smoke writes it)
    rec = run_decode_bench()
    emit(
        "decode_step_hlo_bytes",
        rec["hlo"]["decode_step_bytes_filter_cache"],
        f"requantize={rec['hlo']['decode_step_bytes_requantize']:.0f} "
        f"ratio={rec['hlo']['traffic_ratio']:.3f}",
    )
    return rows


if __name__ == "__main__":
    # Standalone decode-bench entry (CI smoke): writes BENCH_decode.json.
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_decode.json")
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    out = write_decode_json(
        args.json, max_len=args.max_len, n_requests=args.requests,
        new_tokens=args.new_tokens,
    )
    print(json.dumps(out, indent=2, sort_keys=True))
