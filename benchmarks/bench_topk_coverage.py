"""Paper Table II: MP-MRF selection coverage of the true top-k set.

For each query row of a trained layer's attention, compare the MP-MRF
survivor set against the exact top-k (k = survivor count) of the exact
score matrix. The paper reports 91–97 % coverage at optimal ratios.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks._trained import attention_qk, eval_batch, trained_model
from repro.core import filtering as flt


def coverage_for(alphas) -> dict:
    cfg, model, params, ds = trained_model()
    batch = eval_batch(ds)
    q, k, _ = attention_qk(cfg, params, batch, layer=2)
    n = q.shape[2]
    valid = jnp.broadcast_to(
        flt.causal_valid_mask(n, n), q.shape[:2] + (n, n)
    )
    t0 = time.perf_counter()
    res = flt.mpmrf_row_select(q, k, flt.MPMRFConfig(alphas=alphas), valid)
    dt = time.perf_counter() - t0

    exact = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k
    ) / (q.shape[-1] ** 0.5)
    exact = jnp.where(valid, exact, -1e30)

    keep = np.asarray(res.keep_mask)
    exact_np = np.asarray(exact)
    covered, total = 0, 0
    B, H, N, _ = keep.shape
    for b in range(B):
        for h in range(H):
            for i in range(8, N, 7):  # sample rows (dense rows are slow)
                kk = int(keep[b, h, i].sum())
                if kk == 0 or kk > i + 1:
                    continue
                top = np.argpartition(-exact_np[b, h, i], kk - 1)[:kk]
                sel = np.nonzero(keep[b, h, i])[0]
                covered += len(np.intersect1d(top, sel))
                total += kk
    ratio = float(res.keep_mask.sum() / valid.sum())
    return {
        "coverage": covered / max(total, 1),
        "pruning_ratio": 1.0 / max(ratio, 1e-9),
        "us_per_call": dt * 1e6,
    }


def main(emit):
    rows = []
    for alphas in [(0.0, 0.0), (0.1, 0.1), (-0.1, -0.1)]:
        r = coverage_for(alphas)
        r["alphas"] = alphas
        rows.append(r)
        emit(
            f"topk_coverage_a{alphas[0]}_{alphas[1]}",
            r["us_per_call"],
            f"coverage={r['coverage']*100:.1f}% "
            f"ratio={r['pruning_ratio']:.2f}x",
        )
    return rows
