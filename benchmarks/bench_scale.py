"""Multi-device serving scaling sweep (BENCH_scale.json).

Drains the heavy mixed-length trace (4x the BENCH_serving trace — 48
requests, 8-512 token prompts) through ``ReplicatedServeLoop`` at 1, 2
and 4 engine replicas on an ``(N, 1)`` mesh of simulated host devices
(``--xla_force_host_platform_device_count``), one replica per device.

**The scaling metric is tick-normalized.** On a single host CPU the
replicas' dispatches serialize, so wall-clock measures host contention,
not the replica parallelism a real multi-device deployment gets. Each
replica's tick count is what it would execute *concurrently* on its own
device, so the parallel makespan is ``max_r ticks_r`` and

    throughput(N)        = total decode tokens / max_r ticks_r
    scaling_efficiency(N) = ticks(1) / (N * max_r ticks_r(N))

Efficiency < 1 comes from real scheduler effects the bench is meant to
surface — placement imbalance (the uid hash plus least-loaded spill),
wave quantization (ceil(requests / slots) admission waves per replica)
— not from host noise. Wall-clock decode tok/s (serial and the
max-over-replica parallel model) ride along for reference.

The sweep also re-checks the replica contract end-to-end: every
request's token stream at every N must be bit-identical to the N=1 run
(``streams_identical_across_scales``) — placement must never leak into
outputs.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys


def _force_host_devices(n: int) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+\s*", "", flags
    ).strip()
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_scale.json")
    ap.add_argument("--simulate-devices", type=int, default=4,
                    help="fake this many host devices (set before jax "
                         "imports); the sweep runs every replica count "
                         "in --sweep that fits")
    ap.add_argument("--sweep", default="1,2,4",
                    help="comma-separated replica counts")
    ap.add_argument("--trace-repeats", type=int, default=4,
                    help="heavy trace = BENCH_serving trace x this "
                         "(4 => 48 requests)")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--num-pages", type=int, default=16,
                    help="page-pool size PER REPLICA (16 oversubscribes "
                         "4 slots x 9 blocks and exercises preemption)")
    args = ap.parse_args(argv)

    _force_host_devices(args.simulate_devices)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax
    import numpy as np

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from bench_throughput import SERVING_TRACE, _serve_model

    from repro.kernels.ops import _default_interpret
    from repro.launch.mesh import make_mesh_compat
    from repro.runtime import ReplicatedServeLoop, Request

    sweep = sorted({int(x) for x in args.sweep.split(",")})
    sweep = [n for n in sweep if n <= len(jax.devices())]
    lengths = list(SERVING_TRACE) * args.trace_repeats
    cfg, model, params = _serve_model()

    record = {
        "schema": 1,
        "host_backend": jax.default_backend(),
        "kernel_mode": (
            "interpret" if _default_interpret() else "compiled"
        ),
        "simulated_devices": len(jax.devices()),
        "trace": {
            "prompt_lengths": lengths,
            "requests": len(lengths),
            "new_tokens": args.new_tokens,
            "batch_slots": args.batch_slots,
            "num_pages_per_replica": args.num_pages,
        },
        "replicas": {},
    }

    prompt_rng = np.random.default_rng(0)
    prompts = [
        prompt_rng.integers(1, cfg.vocab_size - 1, size=int(L)).tolist()
        for L in lengths
    ]

    streams_by_n = {}
    for n in sweep:
        mesh = make_mesh_compat((n, 1), ("data", "model"))
        loop = ReplicatedServeLoop(
            model, params, mesh=mesh,
            batch_slots=args.batch_slots,
            max_len=528, prefill_chunk=64,
            num_pages=args.num_pages,
            rng=jax.random.PRNGKey(0),
        )
        for uid, prompt in enumerate(prompts):
            loop.submit(Request(
                uid=uid, prompt=list(prompt),
                max_new_tokens=args.new_tokens,
            ))
        import time
        t0 = time.perf_counter()
        done = loop.run_until_drained(max_ticks=100_000)
        wall = time.perf_counter() - t0
        assert len(done) == len(lengths), (n, len(done))

        streams_by_n[n] = {r.uid: tuple(r.tokens_out) for r in done}
        m = loop.merged_metrics()
        per_ticks = [e.metrics.ticks for e in loop.engines]
        max_ticks = max(per_ticks)
        counts = [0] * n
        for r in loop.placement.values():
            counts[r] += 1
        record["replicas"][str(n)] = {
            "decode_tokens": m.decode_tokens,
            "ticks_per_replica": per_ticks,
            "max_ticks": max_ticks,
            "decode_tok_per_tick": m.decode_tokens / max(max_ticks, 1),
            "wall_seconds": wall,
            "decode_tok_s_serial_wall": (
                m.decode_tokens
                / max(sum(e.metrics.decode_time
                          for e in loop.engines), 1e-9)
            ),
            "decode_tok_s_parallel_model": (
                m.decode_tokens
                / max(max(e.metrics.decode_time
                          for e in loop.engines), 1e-9)
            ),
            "goodput_tokens": sum(
                len(r.tokens_out) for r in done
            ),
            "completed": len(done),
            "preemptions": m.preemptions,
            "peak_pages_per_replica": [
                e.metrics.peak_pages_in_use for e in loop.engines
            ],
            "placement_counts": counts,
        }
        print(f"[scale] {n} replica(s): {m.decode_tokens} decode tok, "
              f"ticks/replica {per_ticks}, "
              f"{m.decode_tokens / max(max_ticks, 1):.2f} tok/tick, "
              f"placement {counts}, {m.preemptions} preemptions")

    base_ticks = record["replicas"][str(sweep[0])]["max_ticks"]
    record["scaling_efficiency"] = {
        str(n): base_ticks / (n * record["replicas"][str(n)]["max_ticks"])
        for n in sweep if n != sweep[0]
    }
    base_streams = streams_by_n[sweep[0]]
    record["streams_identical_across_scales"] = all(
        streams_by_n[n] == base_streams for n in sweep
    )
    print(f"[scale] efficiency {record['scaling_efficiency']}, "
          f"streams identical: "
          f"{record['streams_identical_across_scales']}")

    with open(args.json, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    print(f"[scale] wrote {args.json}")
    return record


if __name__ == "__main__":
    main()
