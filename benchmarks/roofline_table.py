"""§Roofline: assemble the per-(arch × shape × mesh) roofline table from
the dry-run artifacts (see repro/launch/dryrun.py)."""

from __future__ import annotations

import json
import os
from typing import List, Optional

from repro.analysis.roofline import RooflineReport, build_report

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_reports(mesh: str = "single") -> List[RooflineReport]:
    base = os.path.join(ART, mesh)
    reports = []
    if not os.path.isdir(base):
        return reports
    from repro.analysis.memory_model import hbm_traffic_bytes
    from repro.configs import shapes_for_arch
    from repro.configs.registry import get_config

    for name in sorted(os.listdir(base)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(base, name)) as f:
            a = json.load(f)
        cfg = get_config(a["arch"])
        shape_cfg = next(
            s for s in shapes_for_arch(a["arch"]) if s.name == a["shape"]
        )
        # mirror repro.launch.dryrun.microbatches_for without importing
        # it (the dryrun module force-sets 512 fake devices on import)
        model_shards = 16  # 'model' axis of both production meshes
        dp = a["chips"] // model_shards
        mb = 16 if a["arch"] == "qwen3-moe-235b-a22b" else 8
        mb = min(mb, max(1, shape_cfg.global_batch // dp))
        analytic = hbm_traffic_bytes(
            cfg, shape_cfg, a["chips"], model_shards, mb,
            opt_factored=True,
        )["total"]
        reports.append(build_report(
            arch=a["arch"],
            shape=a["shape"],
            mesh_name=a["mesh"],
            chips=a["chips"],
            parsed_flops=a["parsed"]["flops_per_chip"],
            parsed_traffic_bytes=a["parsed"]["traffic_bytes_per_chip"],
            parsed_collective_bytes=a["parsed"]["collective_bytes_per_chip"],
            model_flops=a["model_flops"],
            raw_flops=a["cost_analysis"].get("flops"),
            raw_bytes=a["cost_analysis"].get("bytes accessed"),
            peak_memory_bytes=(
                a["memory_analysis"].get("temp_size_in_bytes", 0)
                + a["memory_analysis"].get("argument_size_in_bytes", 0)
            ),
            analytic_traffic_bytes=analytic,
        ))
    return reports


def format_table(reports: List[RooflineReport]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
        f"{'coll_s':>10s} {'bound':>10s} {'MF/HLO':>7s} {'roofline%':>9s} "
        f"{'mem GB':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.compute_s:10.4f} "
            f"{r.memory_s:10.4f} {r.collective_s:10.4f} {r.dominant:>10s} "
            f"{r.useful_ratio:7.3f} {100*r.roofline_fraction:8.1f}% "
            f"{(r.peak_memory_bytes or 0)/2**30:7.2f}"
        )
    return "\n".join(lines)


def main(emit):
    rows = []
    for mesh in ("single", "multi"):
        for r in load_reports(mesh):
            emit(
                f"roofline_{mesh}_{r.arch}_{r.shape}",
                r.bound_time_s * 1e6,
                f"bound={r.dominant} frac={r.roofline_fraction:.3f} "
                f"coll_s={r.collective_s:.3f}",
            )
            rows.append(r.to_dict())
    return rows
