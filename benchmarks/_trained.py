"""Shared trained model for the accuracy-style benchmarks.

The paper evaluates MP-MRF on pretrained BERT/GPT-2/ViT checkpoints; no
pretrained weights exist offline, so the accuracy benchmarks measure the
same quantities (pruning ratio ↔ quality delta, top-k coverage) on a
small LM trained in-repo on the structured synthetic corpus — trained
attention is peaked, which is the property the paper's claims rest on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import EnergonConfig
from repro.data import TokenDataset
from repro.models import LMModel
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, TrainLoop

VOCAB = 96
SEQ = 96


@functools.lru_cache(maxsize=1)
def trained_model():
    cfg = ModelConfig(
        name="bench", family="dense", num_layers=4, d_model=96,
        num_heads=6, num_kv_heads=6, head_dim=16, d_ff=192,
        vocab_size=VOCAB, dtype="float32", remat="none",
        energon=EnergonConfig(impl="dense"),
    )
    model = LMModel(cfg)
    ds = TokenDataset(VOCAB, seq_len=SEQ, global_batch=16, seed=0,
                      corpus_tokens=40000)
    loop = TrainLoop(
        model,
        TrainConfig(total_steps=250, log_every=50,
                    optimizer=AdamWConfig(learning_rate=2e-3)),
        ds,
    )
    result = loop.run()
    return cfg, model, result["params"], ds


def eval_batch(ds, seed_step: int = 10**6):
    b = ds.batch_at(seed_step)
    return {k: jnp.asarray(v) for k, v in b.items()}


def attention_qk(cfg, params, batch, layer: int = 2):
    """Extract post-RoPE q/k of one trained layer for filter analysis."""
    from repro.models import layers as L
    from repro.models.attention import _project_qkv

    x = L.embed_tokens(params["embed"], batch["inputs"]) * (
        cfg.d_model ** 0.5
    )
    blk = jax.tree.map(lambda a: a[layer], params["blocks"])
    # run the stack up to `layer` for realistic inputs
    for i in range(layer):
        blk_i = jax.tree.map(lambda a: a[i], params["blocks"])
        from repro.models.transformer import apply_block

        x, _ = apply_block(
            blk_i, x, cfg.energon,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            rope_theta=cfg.rope_theta, use_qk_norm=cfg.use_qk_norm,
            activation=cfg.activation, norm=cfg.norm,
            layer_index=10**9,
        )
    xn = L.apply_norm(cfg.norm, blk["norm_attn"], x)
    n = x.shape[1]
    q, k, v = _project_qkv(
        blk["attn"], xn, jnp.arange(n)[None, :], cfg.use_qk_norm,
        cfg.rope_theta,
    )
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))
