"""Paper Fig. 15-A: filtering-round design-space exploration.

Compares round configurations (a) 1-2, (b) 2-4, (c) 1-2-4, (d) 2-4-8 at
a matched ~4× pruning ratio: quality (attention-output RMSE on trained
q/k), achieved ratio, and the integer-op cost per query (the ASIC cycle
proxy: Σ_r (survivors entering round r) × d, with Fig. 7 reuse making a
round cost only its remainder plane). The paper concludes 2-4 wins.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks._trained import attention_qk, eval_batch, trained_model
from repro.core import filtering as flt
from repro.core import sparse_attention as spa

CONFIGS = {
    "1-2": ((1, 2), (0.0, 0.35)),
    "2-4": ((2, 4), (0.0, 0.35)),
    "1-2-4": ((1, 2, 4), (0.0, 0.0, 0.12)),
    "2-4-8": ((2, 4, 8), (0.0, 0.0, 0.12)),
}


def filtering_int_ops(res: flt.FilterResult, bits, n: int, d: int) -> float:
    """Integer multiply-ops per query, with result reuse: round r costs
    survivors(r-1) × d × (plane width fraction)."""
    fracs = np.asarray(res.survivor_fraction).reshape(
        len(bits), -1
    ).mean(axis=1)
    entering = [1.0] + list(fracs[:-1])
    ops = 0.0
    prev_bits = 0
    for b, frac_in in zip(bits, entering):
        ops += frac_in * n * d * (b - prev_bits) / max(bits[-1], 1)
        prev_bits = b
    return ops


def run():
    cfg, model, params, ds = trained_model()
    batch = eval_batch(ds)
    q, k, v = attention_qk(cfg, params, batch, layer=2)
    n, d = q.shape[2], q.shape[3]
    valid = jnp.broadcast_to(
        flt.causal_valid_mask(n, n), q.shape[:2] + (n, n)
    )
    dense = spa.dense_attention(q, k, v, valid)
    dense_rms = float(jnp.sqrt(jnp.mean(dense ** 2)))

    rows = []
    for name, (bits, alphas) in CONFIGS.items():
        t0 = time.perf_counter()
        res = flt.mpmrf_row_select(
            q, k, flt.MPMRFConfig(round_bits=bits, alphas=alphas), valid
        )
        out = spa.masked_sparse_attention(q, k, v, res.keep_mask)
        dt = time.perf_counter() - t0
        kept = float(res.keep_mask.sum() / valid.sum())
        rmse = float(jnp.sqrt(jnp.mean((out - dense) ** 2)))
        rows.append({
            "config": name,
            "pruning_ratio": 1.0 / max(kept, 1e-9),
            "rel_rmse": rmse / dense_rms,
            "int_ops_per_query": filtering_int_ops(res, bits, n, d),
            "us_per_call": dt * 1e6,
        })
    return rows


def main(emit):
    rows = run()
    for r in rows:
        emit(
            f"dse_rounds_{r['config']}", r["us_per_call"],
            f"ratio={r['pruning_ratio']:.2f}x rel_rmse={r['rel_rmse']:.3f} "
            f"int_ops={r['int_ops_per_query']:.0f}",
        )
    return rows
