"""Paper Fig. 4 + Fig. 10: pruning ratio vs quality, α-parameter sweep.

25 (α₀, α₁) configurations exactly as §V-A ("for each round we set αr
from -0.2 to 0.2 with a step of 0.1"); for each we measure the achieved
pruning ratio and the quality deltas vs dense attention:
  * perplexity delta of the trained LM (task-level, the paper's metric),
  * attention-output RMSE (mechanism-level).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from benchmarks._trained import eval_batch, trained_model
from repro.core import EnergonConfig
from repro.models import LMModel

ALPHAS = [-0.2, -0.1, 0.0, 0.1, 0.2]


def run() -> list:
    cfg, model, params, ds = trained_model()
    batch = eval_batch(ds)

    dense_loss, _ = model.loss(params, batch)
    dense_ppl = float(jnp.exp(dense_loss))

    rows = []
    for a0 in ALPHAS:
        for a1 in ALPHAS:
            e = EnergonConfig(
                impl="mpmrf_row", alphas=(a0, a1), min_prune_layer=2
            )
            m = LMModel(dataclasses.replace(cfg, energon=e))
            t0 = time.perf_counter()
            loss, _ = m.loss(params, batch)
            dt = time.perf_counter() - t0
            ppl = float(jnp.exp(loss))

            # measured pruning ratio on a pruned layer
            from benchmarks._trained import attention_qk
            from repro.core import filtering as flt

            q, k, _ = attention_qk(cfg, params, batch, layer=2)
            n = q.shape[2]
            valid = jnp.broadcast_to(
                flt.causal_valid_mask(n, n), q.shape[:2] + (n, n)
            )
            res = flt.mpmrf_row_select(
                q, k, flt.MPMRFConfig(alphas=(a0, a1)), valid
            )
            kept = float(res.keep_mask.sum() / valid.sum())
            rows.append({
                "alpha0": a0, "alpha1": a1,
                "pruning_ratio": 1.0 / max(kept, 1e-9),
                "ppl": ppl,
                "ppl_delta": ppl - dense_ppl,
                "dense_ppl": dense_ppl,
                "us_per_call": dt * 1e6,
            })
    return rows


def main(emit):
    rows = run()
    best = max(
        (r for r in rows if r["ppl_delta"] <= 0.05 * r["dense_ppl"]),
        key=lambda r: r["pruning_ratio"],
        default=max(rows, key=lambda r: -r["ppl_delta"]),
    )
    for r in rows:
        emit(
            f"pruning_accuracy_a{r['alpha0']}_{r['alpha1']}",
            r["us_per_call"],
            f"ratio={r['pruning_ratio']:.2f}x ppl_delta={r['ppl_delta']:+.3f}",
        )
    emit(
        "pruning_accuracy_BEST", best["us_per_call"],
        f"ratio={best['pruning_ratio']:.2f}x "
        f"ppl={best['ppl']:.2f} dense={best['dense_ppl']:.2f}",
    )
    return rows
